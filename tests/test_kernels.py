"""Kernel-vs-oracle validation (Pallas interpret mode on CPU).

Per instructions: sweep shapes/dtypes per kernel and assert_allclose against
the ref.py pure-jnp oracle.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import operators as om
from repro.core.l0 import compute_gram_stats, score_tuples_qr
from repro.core.sis import TaskLayout, build_score_context
from repro.kernels import ops as kops
from repro.kernels.ref import fused_gen_sis_ref, l0_pair_sse_ref, solve3_sse


def _ctx_pair(resid, layout, s, s_pad):
    ctx = build_score_context(resid, layout)
    ctx_pad = build_score_context(resid, layout, s_pad=s_pad)
    return ctx, ctx_pad


def _oracle_scores(op_id, xa, xb, ctx_pad, s, l_b=1e-5, u_b=1e8):
    s_pad = ctx_pad.s_pad
    ap = jnp.full((xa.shape[0], s_pad), 1.0, jnp.float64).at[:, :s].set(xa)
    bp = jnp.full((xb.shape[0], s_pad), 1.0, jnp.float64).at[:, :s].set(xb)
    return np.array(fused_gen_sis_ref(
        op_id, ap, bp,
        jnp.asarray(ctx_pad.membership, jnp.float64),
        jnp.asarray(ctx_pad.y_tilde, jnp.float64),
        jnp.asarray(ctx_pad.counts, jnp.float64),
        ctx_pad.n_residuals, l_b, u_b,
    ))


OPS_SWEEP = [om.ADD, om.SUB, om.MUL, om.DIV, om.ABS_DIFF, om.LOG, om.SQRT,
             om.SQ, om.CB, om.INV, om.EXP, om.NEG_EXP, om.SIX_POW]


@pytest.mark.parametrize("op_id", OPS_SWEEP)
def test_fused_sis_all_ops(rng, op_id):
    b, s, nf = 100, 156, 30
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    layout = TaskLayout.from_task_ids(np.repeat([0, 1], [75, 81]))
    resid = rng.normal(size=(2, s))
    ctx, ctx_pad = _ctx_pair(resid, layout, s, ((s + 127) // 128) * 128)
    got = np.array(kops.fused_gen_sis(
        op_id, jnp.asarray(x[ia], jnp.float32), jnp.asarray(x[ib], jnp.float32),
        ctx, 1e-5, 1e8))
    want = _oracle_scores(op_id, x[ia], x[ib], ctx_pad, s)
    assert np.array_equal(np.isfinite(got), np.isfinite(want))
    f = np.isfinite(want)
    np.testing.assert_allclose(got[f], want[f], atol=5e-6)


@pytest.mark.parametrize("b,s,tasks,n_res,block", [
    (1, 8, 1, 1, 128),       # minimal
    (37, 100, 1, 3, 128),    # unaligned batch
    (256, 129, 2, 1, 128),   # s just over one lane tile
    (300, 400, 3, 2, 256),   # multi-task, multi-residual
    (512, 2400, 1, 10, 512), # kaggle-sized samples, 10 residuals (paper)
])
def test_fused_sis_shape_sweep(rng, b, s, tasks, n_res, block):
    nf = 20
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    resid = rng.normal(size=(n_res, s))
    ctx, ctx_pad = _ctx_pair(resid, layout, s, ((s + 127) // 128) * 128)
    got = np.array(kops.fused_gen_sis(
        om.MUL, jnp.asarray(x[ia], jnp.float32), jnp.asarray(x[ib], jnp.float32),
        ctx, 1e-5, 1e8, block_b=block))
    want = _oracle_scores(om.MUL, x[ia], x[ib], ctx_pad, s)
    assert got.shape == (b,)
    f = np.isfinite(want)
    np.testing.assert_allclose(got[f], want[f], atol=5e-6)


def test_fused_sis_flags_invalid(rng):
    s = 65  # odd point count => linspace contains an exact zero
    x = np.stack([np.linspace(-1, 1, s),            # zero divisor value
                  rng.uniform(0.5, 1.0, s),
                  np.full(s, 2.0)])                 # constant -> zero variance
    layout = TaskLayout.single(s)
    ctx = build_score_context(rng.normal(size=(1, s)), layout)
    got = np.array(kops.fused_gen_sis(
        om.DIV, jnp.asarray(x[[1, 2]], jnp.float32), jnp.asarray(x[[0, 1]], jnp.float32),
        ctx, 1e-5, 1e8))
    assert got[0] == -np.inf       # b/a has inf at the zero crossing
    assert np.isfinite(got[1])
    got2 = np.array(kops.fused_gen_sis(
        om.MUL, jnp.asarray(x[[2]], jnp.float32), jnp.asarray(x[[2]], jnp.float32),
        ctx, 1e-5, 1e8))
    assert got2[0] == -np.inf      # constant*constant -> zero variance


# ---------------------------------------------------------------------------
# ℓ0 tile kernel
# ---------------------------------------------------------------------------

def test_solve3_closed_form_matches_linalg(rng):
    for _ in range(50):
        m3 = rng.normal(size=(3, 3))
        m3 = m3 @ m3.T + 3 * np.eye(3)
        r = rng.normal(size=3)
        yty = float(rng.uniform(10, 20))
        c = np.linalg.solve(m3, r)
        want = yty - c @ r
        got = float(solve3_sse(
            m3[0, 0], m3[1, 1], m3[2, 2], m3[0, 1], m3[0, 2], m3[1, 2],
            r[0], r[1], r[2], yty))
        np.testing.assert_allclose(got, max(want, 0.0), rtol=1e-9)


def test_l0_pair_sse_ref_matches_qr(rng):
    m, s = 20, 90
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.from_task_ids(np.repeat([0, 1], 45))
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    got = np.array(l0_pair_sse_ref(jnp.asarray(x), jnp.asarray(y),
                                   layout.slices, jnp.asarray(pairs)))
    want = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                    jnp.asarray(pairs)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_l0_score_pairs_gram_gather(rng):
    m, s = 25, 80
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.single(s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    got = np.array(kops.l0_score_pairs(stats, jnp.asarray(pairs)))
    want = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                    jnp.asarray(pairs)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("m,s,tasks,block", [
    (50, 60, 1, 128),
    (130, 156, 2, 128),    # unaligned m, multi-task (thermal-like)
    (300, 156, 2, 128),
    (200, 333, 3, 256),    # unaligned samples, 3 tasks
])
def test_l0_search_tiled_exact_topk(rng, m, s, tasks, block):
    x = rng.uniform(0.5, 3.0, (m, s))
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    y = 2 * x[m // 3] * x[m // 2] + rng.normal(0, 0.3, s)
    tuples, sses, n_eval = kops.l0_search_tiled(x, y, layout, n_keep=10,
                                                block=block)
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    ref = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                   jnp.asarray(pairs)))
    order = np.argsort(ref, kind="stable")[:10]
    assert np.array_equal(tuples, pairs[order].astype(np.int64))
    np.testing.assert_allclose(sses, ref[order], rtol=1e-5)
    assert n_eval == m * (m - 1) // 2


# ---------------------------------------------------------------------------
# ℓ0 Gram-gather kernel (widths >= 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s,tasks,width,block_t", [
    (10, 40, 1, 3, 128),     # minimal single-task
    (14, 156, 2, 3, 128),    # thermal-like multi-task
    (14, 60, 2, 4, 128),     # width 4
    (20, 90, 1, 4, 256),     # bigger tile
    (12, 333, 3, 3, 128),    # unaligned samples, 3 tasks
])
def test_l0_gather_kernel_matches_oracle(rng, m, s, tasks, width, block_t):
    from repro.kernels.ref import l0_gather_sse_ref

    x = rng.uniform(0.5, 3.0, (m, s))
    y = 2.0 * x[3] - x[7] + 0.1 * rng.normal(size=s)
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    tuples = np.asarray(
        list(__import__("itertools").combinations(range(m), width)), np.int32)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pack = kops.pack_gram_fp32(stats)
    got = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples),
                                          block_t=block_t, interpret=True))
    oracle = np.asarray(l0_gather_sse_ref(
        pack["gram"], pack["fsum"], pack["bvec"], pack["scal"],
        jnp.asarray(tuples)))
    want = np.asarray(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                      jnp.asarray(tuples)))
    assert got.shape == (len(tuples),)
    # kernel vs pure-jnp oracle: same math, fp32 accumulation-order noise
    np.testing.assert_allclose(got, oracle, rtol=1e-3, atol=1e-4)
    # fp32 pre-pass vs fp64 QR: a ranking-quality bound, not bit equality —
    # phase 2 (backend rescore) restores exact values for the winners
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
    assert np.quantile(rel, 0.99) < 2e-2
    assert np.argmin(got) == np.argmin(want)


def test_l0_gather_padding_is_inert(rng):
    """Block sizes that don't divide block_t get benign padding tuples;
    results must be identical to an aligned call, sliced."""
    m, s = 11, 50
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.single(s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pack = kops.pack_gram_fp32(stats)
    tuples = np.asarray(
        list(__import__("itertools").combinations(range(m), 3)), np.int32)
    full = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples),
                                           block_t=128, interpret=True))
    ragged = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples[:131]),
                                             block_t=128, interpret=True))
    np.testing.assert_array_equal(ragged, full[:131])


def test_l0_search_tiled_planted(rng):
    m, s = 140, 96
    x = rng.uniform(0.5, 3.0, (m, s))
    y = -1.5 * x[7] + 4.0 * x[100]
    tuples, sses, _ = kops.l0_search_tiled(x, y, TaskLayout.single(s), n_keep=3)
    assert tuple(tuples[0]) == (7, 100)
    assert sses[0] < 1e-9
