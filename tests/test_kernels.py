"""Kernel-vs-oracle validation (Pallas interpret mode on CPU).

Per instructions: sweep shapes/dtypes per kernel and assert_allclose against
the ref.py pure-jnp oracle.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import operators as om
from repro.core.l0 import compute_gram_stats, score_tuples_qr
from repro.core.sis import TaskLayout, build_score_context
from repro.kernels import ops as kops
from repro.kernels.ref import fused_gen_sis_ref, l0_pair_sse_ref, solve3_sse


def _ctx_pair(resid, layout, s, s_pad):
    ctx = build_score_context(resid, layout)
    ctx_pad = build_score_context(resid, layout, s_pad=s_pad)
    return ctx, ctx_pad


def _oracle_scores(op_id, xa, xb, ctx_pad, s, l_b=1e-5, u_b=1e8):
    s_pad = ctx_pad.s_pad
    ap = jnp.full((xa.shape[0], s_pad), 1.0, jnp.float64).at[:, :s].set(xa)
    bp = jnp.full((xb.shape[0], s_pad), 1.0, jnp.float64).at[:, :s].set(xb)
    return np.array(fused_gen_sis_ref(
        op_id, ap, bp,
        jnp.asarray(ctx_pad.membership, jnp.float64),
        jnp.asarray(ctx_pad.y_tilde, jnp.float64),
        jnp.asarray(ctx_pad.counts, jnp.float64),
        ctx_pad.n_residuals, l_b, u_b,
    ))


OPS_SWEEP = [om.ADD, om.SUB, om.MUL, om.DIV, om.ABS_DIFF, om.LOG, om.SQRT,
             om.SQ, om.CB, om.INV, om.EXP, om.NEG_EXP, om.SIX_POW]


@pytest.mark.parametrize("op_id", OPS_SWEEP)
def test_fused_sis_all_ops(rng, op_id):
    b, s, nf = 100, 156, 30
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    layout = TaskLayout.from_task_ids(np.repeat([0, 1], [75, 81]))
    resid = rng.normal(size=(2, s))
    ctx, ctx_pad = _ctx_pair(resid, layout, s, ((s + 127) // 128) * 128)
    got = np.array(kops.fused_gen_sis(
        op_id, jnp.asarray(x[ia], jnp.float32), jnp.asarray(x[ib], jnp.float32),
        ctx, 1e-5, 1e8))
    want = _oracle_scores(op_id, x[ia], x[ib], ctx_pad, s)
    assert np.array_equal(np.isfinite(got), np.isfinite(want))
    f = np.isfinite(want)
    np.testing.assert_allclose(got[f], want[f], atol=5e-6)


@pytest.mark.parametrize("b,s,tasks,n_res,block", [
    (1, 8, 1, 1, 128),       # minimal
    (37, 100, 1, 3, 128),    # unaligned batch
    (256, 129, 2, 1, 128),   # s just over one lane tile
    (300, 400, 3, 2, 256),   # multi-task, multi-residual
    (512, 2400, 1, 10, 512), # kaggle-sized samples, 10 residuals (paper)
])
def test_fused_sis_shape_sweep(rng, b, s, tasks, n_res, block):
    nf = 20
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    resid = rng.normal(size=(n_res, s))
    ctx, ctx_pad = _ctx_pair(resid, layout, s, ((s + 127) // 128) * 128)
    got = np.array(kops.fused_gen_sis(
        om.MUL, jnp.asarray(x[ia], jnp.float32), jnp.asarray(x[ib], jnp.float32),
        ctx, 1e-5, 1e8, block_b=block))
    want = _oracle_scores(om.MUL, x[ia], x[ib], ctx_pad, s)
    assert got.shape == (b,)
    f = np.isfinite(want)
    np.testing.assert_allclose(got[f], want[f], atol=5e-6)


def test_fused_sis_flags_invalid(rng):
    s = 65  # odd point count => linspace contains an exact zero
    x = np.stack([np.linspace(-1, 1, s),            # zero divisor value
                  rng.uniform(0.5, 1.0, s),
                  np.full(s, 2.0)])                 # constant -> zero variance
    layout = TaskLayout.single(s)
    ctx = build_score_context(rng.normal(size=(1, s)), layout)
    got = np.array(kops.fused_gen_sis(
        om.DIV, jnp.asarray(x[[1, 2]], jnp.float32), jnp.asarray(x[[0, 1]], jnp.float32),
        ctx, 1e-5, 1e8))
    assert got[0] == -np.inf       # b/a has inf at the zero crossing
    assert np.isfinite(got[1])
    got2 = np.array(kops.fused_gen_sis(
        om.MUL, jnp.asarray(x[[2]], jnp.float32), jnp.asarray(x[[2]], jnp.float32),
        ctx, 1e-5, 1e8))
    assert got2[0] == -np.inf      # constant*constant -> zero variance


# ---------------------------------------------------------------------------
# ℓ0 tile kernel
# ---------------------------------------------------------------------------

def test_solve3_closed_form_matches_linalg(rng):
    for _ in range(50):
        m3 = rng.normal(size=(3, 3))
        m3 = m3 @ m3.T + 3 * np.eye(3)
        r = rng.normal(size=3)
        yty = float(rng.uniform(10, 20))
        c = np.linalg.solve(m3, r)
        want = yty - c @ r
        got = float(solve3_sse(
            m3[0, 0], m3[1, 1], m3[2, 2], m3[0, 1], m3[0, 2], m3[1, 2],
            r[0], r[1], r[2], yty))
        np.testing.assert_allclose(got, max(want, 0.0), rtol=1e-9)


def test_l0_pair_sse_ref_matches_qr(rng):
    m, s = 20, 90
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.from_task_ids(np.repeat([0, 1], 45))
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    got = np.array(l0_pair_sse_ref(jnp.asarray(x), jnp.asarray(y),
                                   layout.slices, jnp.asarray(pairs)))
    want = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                    jnp.asarray(pairs)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_l0_score_pairs_gram_gather(rng):
    m, s = 25, 80
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.single(s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    got = np.array(kops.l0_score_pairs(stats, jnp.asarray(pairs)))
    want = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                    jnp.asarray(pairs)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("m,s,tasks,block", [
    (50, 60, 1, 128),
    (130, 156, 2, 128),    # unaligned m, multi-task (thermal-like)
    (300, 156, 2, 128),
    (200, 333, 3, 256),    # unaligned samples, 3 tasks
])
def test_l0_search_tiled_exact_topk(rng, m, s, tasks, block):
    x = rng.uniform(0.5, 3.0, (m, s))
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    y = 2 * x[m // 3] * x[m // 2] + rng.normal(0, 0.3, s)
    tuples, sses, n_eval = kops.l0_search_tiled(x, y, layout, n_keep=10,
                                                block=block)
    pairs = np.stack(np.triu_indices(m, 1), 1).astype(np.int32)
    ref = np.array(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                   jnp.asarray(pairs)))
    order = np.argsort(ref, kind="stable")[:10]
    assert np.array_equal(tuples, pairs[order].astype(np.int64))
    np.testing.assert_allclose(sses, ref[order], rtol=1e-5)
    assert n_eval == m * (m - 1) // 2


# ---------------------------------------------------------------------------
# ℓ0 Gram-gather kernel (widths >= 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s,tasks,width,block_t", [
    (10, 40, 1, 3, 128),     # minimal single-task
    (14, 156, 2, 3, 128),    # thermal-like multi-task
    (14, 60, 2, 4, 128),     # width 4
    (20, 90, 1, 4, 256),     # bigger tile
    (12, 333, 3, 3, 128),    # unaligned samples, 3 tasks
    (12, 60, 1, 5, 128),     # width 5 (generic unrolled elimination)
    (10, 50, 2, 6, 128),     # width 6, multi-task
])
def test_l0_gather_kernel_matches_oracle(rng, m, s, tasks, width, block_t):
    from repro.kernels.ref import l0_gather_sse_ref

    x = rng.uniform(0.5, 3.0, (m, s))
    y = 2.0 * x[3] - x[7] + 0.1 * rng.normal(size=s)
    ids = np.sort(rng.integers(0, tasks, s))
    layout = TaskLayout.from_task_ids(ids) if tasks > 1 else TaskLayout.single(s)
    tuples = np.asarray(
        list(__import__("itertools").combinations(range(m), width)), np.int32)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pack = kops.pack_gram_fp32(stats)
    got = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples),
                                          block_t=block_t, interpret=True))
    oracle = np.asarray(l0_gather_sse_ref(
        pack["gram"], pack["fsum"], pack["bvec"], pack["scal"],
        jnp.asarray(tuples)))
    want = np.asarray(score_tuples_qr(jnp.asarray(x), jnp.asarray(y), layout,
                                      jnp.asarray(tuples)))
    assert got.shape == (len(tuples),)
    # kernel vs pure-jnp oracle: same math, fp32 accumulation-order noise
    np.testing.assert_allclose(got, oracle, rtol=1e-3, atol=1e-4)
    # fp32 pre-pass vs fp64 QR: a ranking-quality bound, not bit equality —
    # phase 2 (backend rescore) restores exact values for the winners
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
    assert np.quantile(rel, 0.99) < 2e-2
    assert np.argmin(got) == np.argmin(want)


def test_l0_gather_padding_is_inert(rng):
    """Block sizes that don't divide block_t get benign padding tuples;
    results must be identical to an aligned call, sliced."""
    m, s = 11, 50
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    layout = TaskLayout.single(s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pack = kops.pack_gram_fp32(stats)
    tuples = np.asarray(
        list(__import__("itertools").combinations(range(m), 3)), np.int32)
    full = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples),
                                           block_t=128, interpret=True))
    ragged = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples[:131]),
                                             block_t=128, interpret=True))
    np.testing.assert_array_equal(ragged, full[:131])


# ---------------------------------------------------------------------------
# reduced top-k epilogues (kernels/topk.py + the *_topk wrappers)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("largest", [True, False])
def test_block_topk_matches_stable_sort(rng, largest):
    from repro.kernels.topk import block_topk

    scores = rng.normal(size=(1, 256)).astype(np.float32)
    scores[0, 77] = scores[0, 13]  # exact tie -> lowest position must win
    k, k_pad = 9, 128
    vals, pos = jax.jit(block_topk, static_argnums=(1, 2, 3))(
        jnp.asarray(scores), k, k_pad, largest)
    vals, pos = np.asarray(vals)[0], np.asarray(pos)[0]
    key = -scores[0] if largest else scores[0]
    want = np.argsort(key, kind="stable")[:k]
    assert np.array_equal(pos[:k], want)
    np.testing.assert_array_equal(vals[:k], scores[0][want])
    # sentinel lanes: +-inf values, pos -1
    assert np.all(np.isinf(vals[k:]))
    assert np.all(pos[k:] == -1)


def test_merge_block_topk_tie_order():
    from repro.kernels.topk import merge_block_topk

    # two blocks with an exact cross-block tie: lower global index must win
    vals = jnp.asarray([[5.0, 3.0, -np.inf], [5.0, 4.0, -np.inf]], jnp.float32)
    idx = jnp.asarray([[10, 11, -1], [20, 21, -1]], jnp.int32)
    v, i = merge_block_topk(vals, idx, 3, largest=True)
    assert list(np.asarray(i)) == [10, 20, 21]
    np.testing.assert_array_equal(np.asarray(v), [5.0, 5.0, 4.0])


def test_fused_sis_topk_matches_reduce_host(rng):
    from repro.core.sis import ReducedBlock

    b, s, nf = 300, 156, 30
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    layout = TaskLayout.from_task_ids(np.repeat([0, 1], [75, 81]))
    ctx = build_score_context(rng.normal(size=(2, s)), layout)
    a1 = jnp.asarray(x[ia], jnp.float32)
    b1 = jnp.asarray(x[ib], jnp.float32)
    full = np.array(kops.fused_gen_sis(om.MUL, a1, b1, ctx, 1e-5, 1e8,
                                       block_b=128))
    ref = ReducedBlock.reduce_host(full, 25)
    vals, idx = kops.fused_gen_sis_topk(om.MUL, a1, b1, ctx, 1e-5, 1e8,
                                        n_keep=25, block_b=128, epilogue_k=32)
    assert np.array_equal(idx, ref.indices)
    np.testing.assert_allclose(vals, ref.scores, rtol=1e-6)
    assert np.all(np.isfinite(vals))


def test_fused_sis_topk_padding_never_selected(rng):
    """131 rows over block_b=128: padding rows must not reach the winners."""
    b, s, nf = 131, 100, 12
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    ctx = build_score_context(rng.normal(size=(1, s)), TaskLayout.single(s))
    vals, idx = kops.fused_gen_sis_topk(
        om.ADD, jnp.asarray(x[ia], jnp.float32), jnp.asarray(x[ib], jnp.float32),
        ctx, 1e-5, 1e8, n_keep=131, block_b=128, epilogue_k=128)
    assert np.all((idx >= 0) & (idx < b))
    assert np.all(np.isfinite(vals))


@pytest.mark.parametrize("width", [3, 5])
def test_l0_topk_tuples_matches_full(rng, width):
    m, s = 12, 70
    x = rng.uniform(0.5, 3.0, (m, s))
    y = 2.0 * x[3] - x[7] + 0.1 * rng.normal(size=s)
    layout = TaskLayout.single(s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y), layout)
    pack = kops.pack_gram_fp32(stats)
    tuples = np.asarray(
        list(__import__("itertools").combinations(range(m), width)), np.int32)
    full = np.asarray(kops.l0_score_tuples(pack, jnp.asarray(tuples),
                                           block_t=128, interpret=True))
    order = np.argsort(full, kind="stable")[:10]
    sses, idx = kops.l0_topk_tuples(pack, jnp.asarray(tuples), n_keep=10,
                                    block_t=128, epilogue_k=32, interpret=True)
    # same fp32 math but a different XLA fusion graph: indices must agree
    # exactly, values up to FMA/fusion ulp noise (fp64 rescore is phase 2)
    assert np.array_equal(idx, order)
    np.testing.assert_allclose(sses, full[order], rtol=1e-4)
    # padding tuples (131 over block_t=128) must never surface as winners
    sses2, idx2 = kops.l0_topk_tuples(pack, jnp.asarray(tuples[:131]),
                                      n_keep=131, block_t=128,
                                      epilogue_k=128, interpret=True)
    assert np.all((idx2 >= 0) & (idx2 < 131))
    assert np.all(np.isfinite(sses2))


def test_fused_sis_topk_bf16_winner_overlap(rng):
    """bf16 operand generation: winner *set* stays close to fp32 (the
    backend's fp64 rescore pins exact ranking downstream)."""
    b, s, nf = 256, 128, 20
    x = rng.uniform(0.5, 3.0, (nf, s))
    ia, ib = rng.integers(0, nf, b), rng.integers(0, nf, b)
    ctx = build_score_context(rng.normal(size=(2, s)), TaskLayout.single(s))
    a1, b1 = jnp.asarray(x[ia]), jnp.asarray(x[ib])
    _, idx32 = kops.fused_gen_sis_topk(
        om.MUL, a1, b1, ctx, 1e-5, 1e8, n_keep=10, block_b=128,
        dtype=jnp.float32)
    _, idx16 = kops.fused_gen_sis_topk(
        om.MUL, a1, b1, ctx, 1e-5, 1e8, n_keep=20, block_b=128,
        dtype=jnp.bfloat16)
    assert np.all((idx16 >= 0) & (idx16 < b))
    # fp32 top-10 contained in bf16 top-20 (rank noise < 2x margin)
    assert len(set(idx32.tolist()) - set(idx16.tolist())) == 0


def test_pack_gram_dtype_variants(rng):
    m, s = 10, 64
    x = rng.uniform(0.5, 3.0, (m, s))
    y = rng.normal(size=s)
    stats = compute_gram_stats(jnp.asarray(x), jnp.asarray(y),
                               TaskLayout.single(s))
    p32 = kops.pack_gram(stats, jnp.float32)
    p16 = kops.pack_gram(stats, jnp.bfloat16)
    assert p32["dtype"] == "float32" and p16["dtype"] == "bfloat16"
    assert p16["gram"].dtype == jnp.bfloat16
    # scal stays fp32 in both: the solve epilogue accumulates in fp32
    assert p16["scal"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(p16["gram"], np.float32),
                               np.asarray(p32["gram"]), rtol=2e-2, atol=1e-2)


def test_l0_search_tiled_planted(rng):
    m, s = 140, 96
    x = rng.uniform(0.5, 3.0, (m, s))
    y = -1.5 * x[7] + 4.0 * x[100]
    tuples, sses, _ = kops.l0_search_tiled(x, y, TaskLayout.single(s), n_keep=3)
    assert tuple(tuples[0]) == (7, 100)
    assert sses[0] < 1e-9
