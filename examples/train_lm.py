"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production loop — checkpointing, restart safety, step monitoring.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen2-1.5b]

Uses a width/depth-reduced config of the selected arch family scaled to
~100M params; the synthetic token stream has copy structure so the loss
visibly drops.  Kill it mid-run and re-run: it resumes from the last
checkpoint bit-exactly (see tests/test_trainer.py).
"""
import argparse
import dataclasses

from repro.configs import get_arch_config
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def hundred_m_config(arch: str):
    base = get_arch_config(arch)
    if base.family == "ssm":
        return dataclasses.replace(
            base, n_layers=8, d_model=512, d_inner=1024, ssm_state=32,
            ssm_head_dim=32, vocab_size=8192, dtype="float32")
    return dataclasses.replace(
        base, n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=max(base.n_kv_heads // 4, 1), head_dim=64,
        d_ff=2048, vocab_size=8192,
        n_experts=min(base.n_experts, 4) if base.n_experts else 0,
        window=min(base.window, 256) if base.window else 0,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    print(f"arch family={cfg.family}  params≈{cfg.param_count/1e6:.0f}M")
    tcfg = TrainerConfig(
        total_steps=args.steps, checkpoint_every=50, batch=8, seq_len=256,
        ckpt_dir=args.ckpt,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))
    out = Trainer(cfg, tcfg).run()
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} over "
          f"{out['steps_run']} steps "
          f"({out['straggler_steps']} straggler steps flagged)")


if __name__ == "__main__":
    main()
