"""Quickstart: find an analytic law with SISSO and ship it, in ~30 lines.

The canonical surface is the sklearn-style estimator in ``repro.api``::

    from repro.api import SissoRegressor, load_artifact

    est = SissoRegressor(max_rung=1, n_dim=2, n_sis=20)
    est.fit(X_train, y_train, names=["radius", "charge", ...])
    #   X: (n_samples, n_features) — sklearn convention

    y_hat = est.predict(X_test)       # compiled descriptor, unseen samples
    r2 = est.score(X_test, y_test)    # sklearn regressor scoring
    d = est.transform(X_test)         # (n_samples, n_dim) descriptor values

    est.save("law.json")              # versioned, data-free JSON artifact
    load_artifact("law.json").predict(X_test)   # identical predictions

Run it:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import SissoRegressor, load_artifact

rng = np.random.default_rng(0)

# tabular data: 120 samples x 5 primary features (sklearn orientation)
X = rng.uniform(0.5, 3.0, size=(120, 5))
names = ["radius", "charge", "mass", "chi", "ea"]

# hidden ground truth the model should rediscover
y = 2.5 * X[:, 0] * X[:, 1] - 1.3 * X[:, 2] ** 2 + 0.7

X_train, X_test = X[:100], X[100:]
y_train, y_test = y[:100], y[100:]

est = SissoRegressor(
    max_rung=1,            # one level of operator composition
    n_dim=2,               # two-term descriptor
    n_sis=20,              # SIS subspace per dimension
    op_names=("add", "sub", "mul", "div", "sq", "sqrt", "inv"),
)
est.fit(X_train, y_train, names=names)

model = est.model()
print(model)
print(f"held-out rmse={np.sqrt(np.mean((est.predict(X_test) - y_test) ** 2)):.2e}"
      f"  r2={est.score(X_test, y_test):.6f}")
print(f"phase timings: {est.fitted_.timings}")
assert est.score(X_test, y_test) > 0.999999

# persistence: save -> load -> identical out-of-sample predictions
path = est.save("/tmp/quickstart_law.json")
reloaded = load_artifact(path)
assert np.array_equal(reloaded.predict(X_test), est.predict(X_test))
print("recovered the planted law, artifact round-trips ✓")
