"""Quickstart: find an analytic law with SISSO in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SissoConfig, SissoRegressor

rng = np.random.default_rng(0)

# tabular data: 5 primary features, 120 samples
X = rng.uniform(0.5, 3.0, size=(5, 120))
names = ["radius", "charge", "mass", "chi", "ea"]

# hidden ground truth the model should rediscover
y = 2.5 * X[0] * X[1] - 1.3 * X[2] ** 2 + 0.7

cfg = SissoConfig(
    max_rung=1,            # one level of operator composition
    n_dim=2,               # two-term descriptor
    n_sis=20,              # SIS subspace per dimension
    op_names=("add", "sub", "mul", "div", "sq", "sqrt", "inv"),
)
fit = SissoRegressor(cfg).fit(X, y, names)

model = fit.best()
print(model)
rows = [f.row for f in model.features]
fv = fit.fspace.values_matrix()[rows]
print(f"rmse={model.rmse(y, fv):.2e}  r2={model.r2(y, fv):.6f}")
print(f"phase timings: {fit.timings}")
assert model.r2(y, fv) > 0.999999
print("recovered the planted law ✓")
