"""Large-data SISSO on the NOMAD-2018-Kaggle-shaped case (paper §III.A.2).

2400-sample single-task band-gap regression with the 11-operator pool and
the paper's ℓ0 batch size; `--full` runs the unreduced combinatorics.

    PYTHONPATH=src python examples/kaggle_bandgap.py [--full]
"""
import sys

from repro.configs.sisso_kaggle import kaggle_bandgap_case
from repro.core import SissoRegressor

case = kaggle_bandgap_case(reduced="--full" not in sys.argv)
print(f"case: {case.name}  X={case.x.shape}  l0_block={case.config.l0_block}")

fit = SissoRegressor(case.config).fit(case.x, case.y, case.names)
best = fit.best()
rows = [f.row for f in best.features]
fv = fit.fspace.values_matrix()[rows]
print(best)
print(f"r2={best.r2(case.y, fv):.6f}")
print(f"candidates screened: {fit.fspace.n_total} "
      f"({fit.fspace.n_candidates_deferred} generated on-the-fly in SIS)")
print(f"phase breakdown (paper Fig. 3d): {fit.timings}")
