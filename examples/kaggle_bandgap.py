"""Large-data SISSO on the NOMAD-2018-Kaggle-shaped case (paper §III.A.2).

2400-sample single-task band-gap regression with the 11-operator pool and
the paper's ℓ0 batch size; `--full` runs the unreduced combinatorics.  Fit
through ``repro.api`` with an 80/20 split: the reported r² is genuine
out-of-sample generalization via the compiled descriptor.

    PYTHONPATH=src python examples/kaggle_bandgap.py [--full]
"""
import sys

import numpy as np

from repro.api import SissoRegressor
from repro.configs.sisso_kaggle import kaggle_bandgap_case

case = kaggle_bandgap_case(reduced="--full" not in sys.argv)
X = case.x.T                       # (n_samples, n_features) api orientation
print(f"case: {case.name}  X={X.shape}  l0_block={case.config.l0_block}")

n_train = int(0.8 * len(case.y))
est = SissoRegressor.from_config(case.config)
est.fit(X[:n_train], case.y[:n_train], names=case.names)

best = est.model()
print(best)
print(f"train r2={est.score(X[:n_train], case.y[:n_train]):.6f}  "
      f"held-out r2={est.score(X[n_train:], case.y[n_train:]):.6f}")

fspace = est.fit_result_.fspace
print(f"candidates screened: {fspace.n_total} "
      f"({fspace.n_candidates_deferred} generated on-the-fly in SIS)")
print(f"descriptor values on 3 unseen samples:\n"
      f"{np.round(est.transform(X[n_train:n_train + 3]), 4)}")
print(f"phase breakdown (paper Fig. 3d): {est.fitted_.timings}")
