"""Batched serving demo: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b] [--tokens 16]

Runs the reduced config of the chosen arch (any of the 10 assigned families,
including SWA ring caches, local/global alternation, SSM states and the
whisper encoder-decoder path).
"""
import argparse
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

ARCH_MODULES = {
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "qwen2.5-32b": "repro.configs.qwen2p5_32b",
    "nemotron-4-15b": "repro.configs.nemotron4_15b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3p5_moe",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCH_MODULES))
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = importlib.import_module(ARCH_MODULES[args.arch]).reduced()
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    n_prompt = 8
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, n_prompt)), jnp.int32)}
    if cfg.family == "vlm":
        prompts["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        prompts["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, 16, cfg.d_model)), jnp.float32)

    n_ctx = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    max_seq = n_ctx + n_prompt + args.tokens
    t0 = time.perf_counter()
    logits, cache = lm.prefill(cfg, params, prompts, max_seq=max_seq)
    step = jax.jit(lambda p, t, c, pos: lm.decode_step(cfg, p, t, c, pos))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for t in range(args.tokens - 1):
        pos = (t + n_prompt) if cfg.family == "audio" else (n_ctx + n_prompt + t)
        logits, cache = step(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"{args.arch} ({cfg.family}): generated {gen.shape} tokens in "
          f"{dt:.2f}s ({args.batch * args.tokens / dt:.1f} tok/s)")
    print("sequences:", gen[:, :10].tolist())


if __name__ == "__main__":
    main()
