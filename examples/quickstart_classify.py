"""Quickstart (classification): find a separating descriptor and ship it.

The classification twin of ``examples/quickstart.py``: same estimator
conventions, but the target is a set of class labels and the search
minimizes the class-domain *overlap* of the descriptor space
(core/problem.py) instead of a least-squares error.  The fitted surface
is the LDA decision boundaries of the winning descriptor::

    from repro.api import SissoClassifier, load_artifact

    clf = SissoClassifier(max_rung=1, n_dim=2, n_sis=20)
    clf.fit(X_train, labels_train, names=[...])
    clf.predict(X_test)            # class labels
    clf.predict_proba(X_test)      # softmax class probabilities
    clf.save("phases.json")        # same versioned artifact pipeline

Run it:

    PYTHONPATH=src python examples/quickstart_classify.py
"""
import numpy as np

from repro.api import SissoClassifier, load_artifact
from repro.data import classification_dataset

# synthetic separable case: the class is decided by the *composed*
# feature f0 * f1 against a threshold, with a margin band
x, labels, names = classification_dataset(n_samples=160, seed=0)
X = x.T                      # (n_samples, n_features), sklearn orientation

X_train, X_test = X[:120], X[120:]
y_train, y_test = labels[:120], labels[120:]

clf = SissoClassifier(
    max_rung=1,            # one level of operator composition
    n_dim=2,               # up to two-term descriptors
    n_sis=20,              # SIS subspace per dimension
    op_names=("add", "sub", "mul", "div"),
)
clf.fit(X_train, y_train, names=names)

model = clf.model(1)       # best 1D descriptor
print(model)
print(f"descriptor overlap count: {model.n_overlap}")
print(f"held-out accuracy: {clf.score(X_test, y_test, dim=1):.4f}")
assert model.n_overlap == 0          # the planted boundary separates
assert clf.score(X_test, y_test, dim=1) == 1.0

# class probabilities from the per-task discriminants
proba = clf.predict_proba(X_test, dim=1)
assert np.allclose(proba.sum(axis=1), 1.0)

# persistence: save -> load -> identical predictions; the artifact
# records the problem kind, so the regressor path refuses to load it
path = clf.save("/tmp/quickstart_phases.json")
reloaded = load_artifact(path)
assert reloaded.problem == "classification"
assert np.array_equal(reloaded.predict(X_test, dim=1),
                      clf.predict(X_test, dim=1))
print("recovered the separating descriptor, artifact round-trips ✓")
