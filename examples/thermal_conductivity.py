"""Multi-task SISSO on the thermal-conductivity-shaped case (paper §III.A.1).

Reproduces the computational shape of the paper's standard-use benchmark:
156 samples in 2 tasks (experimental / calculated), 17 unit-carrying
primary features, the 14-operator pool, on-the-fly last rung — fit through
the sklearn-style ``repro.api`` estimator, with held-out prediction via the
compiled descriptor and an artifact save/load parity check.

    PYTHONPATH=src python examples/thermal_conductivity.py [--full]
"""
import sys

import numpy as np

from repro.api import SissoRegressor, load_artifact
from repro.configs.sisso_thermal import thermal_conductivity_case

case = thermal_conductivity_case(reduced="--full" not in sys.argv)
X = case.x.T                       # (n_samples, n_features) api orientation
print(f"case: {case.name}  X={X.shape}  tasks="
      f"{len(set(case.task_ids))}  ops={len(case.config.op_names)}")

# hold out every 5th sample; multi-task fit needs per-sample task labels
test = np.arange(len(case.y)) % 5 == 0
train = ~test

est = SissoRegressor.from_config(case.config)
est.fit(X[train], case.y[train], names=case.names, units=case.units,
        tasks=case.task_ids[train])

for dim, models in est.models_by_dim.items():
    best = models[0]
    print(f"dim {dim}: sse={best.sse:.4g}  ({len(models)} residual models)")
best = est.model()
print("\nbest model (per-task coefficients):")
print(best)

r2 = est.score(X[test], case.y[test], tasks=case.task_ids[test])
print(f"\nheld-out r2={r2:.6f} on {test.sum()} unseen samples")

# the artifact predicts identically after a save/load round trip
path = est.save("/tmp/thermal_model.json")
same = np.array_equal(
    load_artifact(path).predict(X[test], tasks=case.task_ids[test]),
    est.predict(X[test], tasks=case.task_ids[test]))
print(f"artifact round-trip identical: {same}")
print(f"phase breakdown (paper Fig. 3b): {est.fitted_.timings}")
