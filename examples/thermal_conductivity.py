"""Multi-task SISSO on the thermal-conductivity-shaped case (paper §III.A.1).

Reproduces the computational shape of the paper's standard-use benchmark:
156 samples in 2 tasks (experimental / calculated), 17 unit-carrying
primary features, the 14-operator pool, on-the-fly last rung.

    PYTHONPATH=src python examples/thermal_conductivity.py [--full]
"""
import sys

from repro.configs.sisso_thermal import thermal_conductivity_case
from repro.core import SissoRegressor

case = thermal_conductivity_case(reduced="--full" not in sys.argv)
print(f"case: {case.name}  X={case.x.shape}  tasks="
      f"{len(set(case.task_ids))}  ops={len(case.config.op_names)}")

fit = SissoRegressor(case.config).fit(
    case.x, case.y, case.names, units=case.units, task_ids=case.task_ids)

for dim, models in fit.models_by_dim.items():
    best = models[0]
    print(f"dim {dim}: sse={best.sse:.4g}  ({len(models)} residual models)")
best = fit.best()
print("\nbest model (per-task coefficients):")
print(best)
print(f"\nphase breakdown (paper Fig. 3b): {fit.timings}")
