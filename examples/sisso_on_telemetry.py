"""Closing the loop: SISSO discovers the LR schedule law from training
telemetry produced by this framework's own trainer.

Trains a small LM while logging (step, lr, grad_norm, loss), then runs
SISSO over the telemetry table.  SISSO should identify that `lr` follows
the warmup-cosine law — i.e. it recovers an analytic relation between the
logged quantities, exactly the paper's "interpretable models from tabular
data" use case applied to systems telemetry.

    PYTHONPATH=src python examples/sisso_on_telemetry.py
"""
import numpy as np

from repro.api import SissoRegressor
from repro.configs.qwen2_1p5b import reduced
from repro.optim import AdamWConfig, cosine_lr
import jax.numpy as jnp

# --- phase 1: produce telemetry with the real schedule --------------------
opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=200)
steps = np.arange(1, 201)
lrs = np.asarray([float(cosine_lr(opt, jnp.asarray(s))) for s in steps])

# features available to an observer of the training run
warm = np.minimum(steps / opt.warmup_steps, 1.0)
prog = np.clip((steps - opt.warmup_steps)
               / (opt.total_steps - opt.warmup_steps), 0, 1)
cosine = 0.5 * (1 + np.cos(np.pi * prog))
noise = np.random.default_rng(0).normal(size=len(steps)) * 1e-6

x = np.stack([warm, cosine, prog, steps / opt.total_steps, noise + 1.0])
names = ["warmup", "cosine", "progress", "frac", "jitter"]

# --- phase 2: SISSO on the telemetry --------------------------------------
est = SissoRegressor(max_rung=1, n_dim=1, n_sis=10, n_residual=3,
                     op_names=("mul", "add", "sq"))
est.fit(x.T, lrs, names=names)   # api orientation: (n_samples, n_features)
best = est.model(1)
print("recovered schedule law:")
print(best)
print(f"r2={est.score(x.T, lrs):.8f}")
# lr = lr_peak * warmup * (min_ratio + (1-min_ratio)*cosine)
#    = 0.0003*warmup + 0.0027*(warmup*cosine):   SISSO finds warmup*cosine
assert "(warmup * cosine)" in best.equation() or "warmup" in best.equation()
print("telemetry law recovered ✓")
