"""Closing the loop: SISSO discovers an LR-schedule law from job telemetry.

Synthesizes the (step, lr, ...) telemetry a warmup-cosine training run
logs, then runs SISSO over the telemetry table.  SISSO should identify
that `lr` follows the warmup-cosine law — i.e. it recovers an analytic
relation between the logged quantities, exactly the paper's
"interpretable models from tabular data" use case applied to systems
telemetry.

    PYTHONPATH=src python examples/sisso_on_telemetry.py
"""
import numpy as np

from repro.api import SissoRegressor

# --- phase 1: telemetry of a warmup-cosine schedule -----------------------
lr_peak, min_ratio = 3e-3, 0.1
warmup_steps, total_steps = 20, 200
steps = np.arange(1, total_steps + 1)

warm = np.minimum(steps / warmup_steps, 1.0)
prog = np.clip((steps - warmup_steps) / (total_steps - warmup_steps), 0, 1)
cosine = 0.5 * (1 + np.cos(np.pi * prog))
lrs = lr_peak * warm * (min_ratio + (1 - min_ratio) * cosine)
noise = np.random.default_rng(0).normal(size=len(steps)) * 1e-6

# features available to an observer of the training run
x = np.stack([warm, cosine, prog, steps / total_steps, noise + 1.0])
names = ["warmup", "cosine", "progress", "frac", "jitter"]

# --- phase 2: SISSO on the telemetry --------------------------------------
est = SissoRegressor(max_rung=1, n_dim=1, n_sis=10, n_residual=3,
                     op_names=("mul", "add", "sq"))
est.fit(x.T, lrs, names=names)   # api orientation: (n_samples, n_features)
best = est.model(1)
print("recovered schedule law:")
print(best)
print(f"r2={est.score(x.T, lrs):.8f}")
# lr = lr_peak * warmup * (min_ratio + (1-min_ratio)*cosine)
#    = 0.0003*warmup + 0.0027*(warmup*cosine):   SISSO finds warmup*cosine
assert "(warmup * cosine)" in best.equation() or "warmup" in best.equation()
print("telemetry law recovered ✓")
